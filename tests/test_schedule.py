"""Chunk scheduler (repro.train.schedule) + the fused engine's
single-compile contract.

The scheduler plans every fused dispatch host-side: record-window chunks
split along mixing_due gate runs, padded to one fixed scan length per
compiled variant.  The engine must trace its chunk executable at most
twice per run (once when no gate-split applies) and stay bitwise-equal to
the vmap reference loop for every mixing kind under padding + splitting.
"""

import jax
import numpy as np
import pytest

from conftest import tiny_data_fn as _data_fn
from conftest import tiny_init as _init
from conftest import tiny_loss_fn as _loss_fn
from repro.configs.base import TrainConfig
from repro.core.mixing import MixingConfig, mixing_due
from repro.train import train_population
from repro.train import engine as engine_mod
from repro.train.engine import train_population_sharded
from repro.train.schedule import build_schedule, chunk_ranges, record_boundaries

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# boundaries / ranges edge cases
# ---------------------------------------------------------------------------


def test_record_boundaries_edge_cases():
    assert record_boundaries(1, 25) == [0]          # total_steps=1
    assert record_boundaries(5, 1) == [0, 1, 2, 3, 4]  # record_every=1
    assert record_boundaries(3, 10) == [0, 2]       # record_every > total
    assert record_boundaries(10, 5) == [0, 5, 9]


def test_chunk_ranges_edge_cases():
    assert chunk_ranges(1, 25) == [(0, 1)]
    assert chunk_ranges(5, 1) == [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]
    assert chunk_ranges(3, 10) == [(0, 1), (1, 3)]
    for total, every in [(1, 1), (13, 5), (60, 20), (7, 7), (100, 1)]:
        flat = [s for a, b in chunk_ranges(total, every) for s in range(a, b)]
        assert flat == list(range(total))


# ---------------------------------------------------------------------------
# build_schedule
# ---------------------------------------------------------------------------


def _check_schedule_invariants(sched, total_steps, record_every, mcfg):
    chunks = sched.chunks
    # full coverage, in order
    flat = [s for c in chunks for s in c.steps]
    assert flat == list(range(total_steps))
    # gates are the per-step mixing_due results
    for c in chunks:
        assert c.gates == tuple(mixing_due(s, mcfg) for s in c.steps)
        assert c.mixing == any(c.gates)
        # one fixed pad length per variant
        assert c.pad_len == (sched.mix_pad_len if c.mixing
                             else sched.nomix_pad_len)
        assert c.pad >= 0
        assert len(c.padded_gates()) == len(c.padded_valid()) == c.pad_len
        assert sum(c.padded_valid()) == c.length
    # record chunks reproduce the reference loop's history schedule
    rec = [c.stop - 1 for c in chunks if c.record]
    assert rec == record_boundaries(total_steps, record_every)
    assert len(sched.variants()) <= 2


@pytest.mark.parametrize("kind,kw", [
    ("wash", dict(base_p=0.5)),
    ("papa", dict(papa_every=10)),
    ("papa_all", dict(papa_all_every=7)),
    ("none", dict()),
])
@pytest.mark.parametrize("total,every", [
    (1, 25),      # total_steps=1
    (9, 1),       # record_every=1
    (3, 10),      # record_every > total_steps
    (60, 25),
])
def test_build_schedule_invariants(kind, kw, total, every):
    mcfg = MixingConfig(kind=kind, mode="bucketed", **kw)
    sched = build_schedule(total, every, mcfg)
    _check_schedule_invariants(sched, total, every, mcfg)


def test_gate_run_splitting_produces_both_variants():
    """PAPA with T=10 inside 25-step record windows: no-mix spans land on
    the collective-free variant, each mix step on the collective one."""
    mcfg = MixingConfig(kind="papa", papa_every=10)
    sched = build_schedule(60, 25, mcfg)
    assert sched.variants() == (False, True)
    mix_chunks = [c for c in sched.chunks if c.mixing]
    # papa fires at 10, 20, 30, 40, 50 — each its own length-1 mix chunk
    assert [c.start for c in mix_chunks] == [10, 20, 30, 40, 50]
    assert all(c.length == 1 for c in mix_chunks)
    assert sched.mix_pad_len == 1
    # split chunks carry uniform gates; only window-final chunks record
    for c in sched.chunks:
        assert set(c.gates) in ({True}, {False})
    assert [c.stop - 1 for c in sched.chunks if c.record] == [0, 25, 50, 59]


def test_wash_and_none_keep_single_variant():
    wash = build_schedule(13, 5, MixingConfig(kind="wash", mode="bucketed"))
    assert wash.variants() == (True,)           # single dispatch per window
    assert [c.length for c in wash.chunks] == [1, 5, 5, 2]
    assert wash.mix_pad_len == 5
    none = build_schedule(13, 5, MixingConfig(kind="none"))
    assert none.variants() == (False,)          # collective-free throughout


def test_no_split_keeps_one_chunk_per_window():
    mcfg = MixingConfig(kind="papa", papa_every=10)
    sched = build_schedule(60, 25, mcfg, split_gate_runs=False)
    assert [(c.start, c.stop) for c in sched.chunks] == chunk_ranges(60, 25)
    assert all(c.record for c in sched.chunks)
    # mixed-gate windows ride the collective variant with inner gates
    mixed = [c for c in sched.chunks if c.mixing]
    assert any(set(c.gates) == {True, False} for c in mixed)


def test_mixing_window_splits_gate_runs():
    """Fig. 5b ablation windows (start/stop_step) must split like periods."""
    mcfg = MixingConfig(kind="wash", mode="bucketed", start_step=4,
                        stop_step=8)
    sched = build_schedule(12, 12, mcfg)
    assert sched.variants() == (False, True)
    spans = [(c.start, c.stop, c.mixing) for c in sched.chunks]
    assert spans == [(0, 1, False), (1, 4, False), (4, 8, True),
                     (8, 12, False)]


# ---------------------------------------------------------------------------
# engine execution: parity under padding/splitting + the compile-count guard
# ---------------------------------------------------------------------------


def _parity(kind, total, every, **mix_kw):
    tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                       total_steps=total, batch_size=4)
    mcfg = MixingConfig(kind=kind, mode="bucketed", **mix_kw)
    ref = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=every
    )
    engine_mod.reset_chunk_trace_count()
    fused = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=every
    )
    traces = engine_mod.chunk_trace_count()
    sched = build_schedule(total, every, mcfg)
    assert traces == len(sched.variants()) <= 2, (kind, total, every, traces)
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.population),
        jax.tree_util.tree_leaves(fused.population),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.comm_scalars == fused.comm_scalars
    assert ref.history["step"] == fused.history["step"]
    np.testing.assert_allclose(
        ref.history["comm"], fused.history["comm"], rtol=0, atol=0
    )
    return sched, traces


@pytest.mark.parametrize("kind,kw", [
    ("wash", dict(base_p=0.5)),
    ("wash_opt", dict(base_p=0.5)),
    ("papa", dict(papa_every=3, papa_alpha=0.9)),
    ("none", dict()),
])
@pytest.mark.parametrize("total,every", [
    (1, 25),      # total_steps=1: one length-1 chunk
    (5, 1),       # record_every=1: per-step chunks, zero padding
    (7, 10),      # record_every > total_steps: [0,1) + ragged tail
])
def test_padded_split_execution_bitwise_parity(kind, kw, total, every):
    _parity(kind, total, every, **kw)


def test_no_split_execution_matches_reference():
    """split_gate_runs=False (PR 1's one-dispatch-per-window shape, with
    inner gates masking no-mix steps) must still match the reference
    bitwise and still compile each variant once."""
    tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                       total_steps=13, batch_size=4)
    mcfg = MixingConfig(kind="papa", papa_every=5, papa_alpha=0.9)
    ref = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=5
    )
    engine_mod.reset_chunk_trace_count()
    fused = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=5,
        split_gate_runs=False,
    )
    sched = build_schedule(13, 5, mcfg, split_gate_runs=False)
    assert engine_mod.chunk_trace_count() == len(sched.variants()) <= 2
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.population),
        jax.tree_util.tree_leaves(fused.population),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.comm_scalars == fused.comm_scalars


def test_compile_count_one_without_split_two_with():
    """The fused chunk fn traces exactly once per variant: WASH (gates all
    on) compiles one executable; a PAPA pattern that exercises both split
    variants compiles two — never more, for any chunk-length mix."""
    _, traces = _parity("wash", 13, 5, base_p=0.5)
    assert traces == 1                      # no gate-split applies
    sched, traces = _parity("papa", 13, 5, papa_every=5, papa_alpha=0.9)
    assert sched.variants() == (False, True)
    assert traces == 2                      # both variants, once each
