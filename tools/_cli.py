"""Shared argparse scaffolding for the ``tools/`` scripts.

Every tool exposes the same two-symbol surface so
``tools/check_cli_help.py`` can lint them like the launchers:

* ``build_parser() -> argparse.ArgumentParser`` — the full flag surface,
  constructed without side effects (no file IO, no jax import);
* ``main(argv=None) -> int`` — parses with that parser and runs.

:func:`make_parser` builds the parser skeleton from the tool's module
docstring (first line becomes the ``--help`` description, the ``Usage:``
block is preserved as the epilog), so the docstring and the CLI cannot
drift apart.
"""

from __future__ import annotations

import argparse
from typing import Optional


def make_parser(doc: Optional[str], **kwargs) -> argparse.ArgumentParser:
    """ArgumentParser seeded from a tool's module docstring: description
    = first docstring line, epilog = its ``Usage:`` block (if any)."""
    doc = (doc or "").strip()
    lines = doc.splitlines()
    description = lines[0] if lines else None
    epilog = None
    for i, line in enumerate(lines):
        if line.lstrip().lower().startswith("usage"):
            epilog = "\n".join(lines[i:]).strip()
            break
    kwargs.setdefault("description", description)
    kwargs.setdefault("epilog", epilog)
    kwargs.setdefault("formatter_class", argparse.RawDescriptionHelpFormatter)
    return argparse.ArgumentParser(**kwargs)
