#!/usr/bin/env python3
"""Validate telemetry JSONL event streams against the documented schema.

Stdlib-only (CI runs it on raw launcher output, no jax import).  Checks,
per ``docs/OBSERVABILITY.md``:

  * every line is one JSON object with a ``kind`` from the known set;
  * line 1 is the provenance record (jax version, backend, device kind,
    device count, platform, timestamps);
  * every non-provenance record has a ``name`` matching
    ``[a-z0-9_.]+`` (dot-separated lowercase) and a float ``ts``;
  * spans carry ``dur_s >= 0``;
  * metric snapshot lines are internally consistent — histograms have
    ``len(counts) == len(edges) + 1`` and ``sum(counts) == count``,
    counters are non-negative;
  * ``train.comm_volume`` events replay exactly: re-running the same
    float64 adds (``mix_steps`` additions of ``comm_per_mix_step``, in
    stream order) must reproduce each event's cumulative ``comm_total``
    bit-for-bit — the checker-side mirror of the engines' exact
    host-side WASH comm accounting.

Usage::

    python tools/check_metrics_schema.py out.jsonl [more.jsonl ...]
    python tools/check_metrics_schema.py --require-comm train.jsonl

``--require-comm`` additionally fails streams containing NO comm-volume
events (the CI train smoke must produce them).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict, List

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _cli  # noqa: E402

KINDS = {"provenance", "span", "event", "compile", "metric"}
NAME_RE = re.compile(r"^[a-z0-9_.]+$")
PROVENANCE_FIELDS = ("ts", "timestamp", "jax_version", "backend",
                     "device_kind", "device_count", "platform")


def check_stream(path: str, require_comm: bool = False) -> List[str]:
    """Return a list of violation messages (empty = valid)."""
    errors: List[str] = []

    def err(lineno: int, msg: str) -> None:
        errors.append(f"{path}:{lineno}: {msg}")

    try:
        with open(path) as f:
            lines = f.read().splitlines()
    except OSError as e:
        return [f"{path}: unreadable: {e}"]
    if not lines:
        return [f"{path}: empty stream (expected a provenance line)"]

    comm_replay = 0.0
    comm_events = 0
    counters_seen: Dict[str, float] = {}

    for i, line in enumerate(lines, 1):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            err(i, f"not valid JSON: {e}")
            continue
        if not isinstance(rec, dict):
            err(i, f"expected a JSON object, got {type(rec).__name__}")
            continue
        kind = rec.get("kind")
        if kind not in KINDS:
            err(i, f"unknown kind {kind!r} (expected one of {sorted(KINDS)})")
            continue

        if i == 1:
            if kind != "provenance":
                err(i, f"first record must be provenance, got {kind!r}")
            continue
        if kind == "provenance":
            if i != 1:
                err(i, "provenance must be the first record only")
            continue

        name = rec.get("name")
        if not isinstance(name, str) or not NAME_RE.match(name):
            err(i, f"bad metric/event name {name!r} "
                   f"(expected lowercase dotted [a-z0-9_.]+)")
        ts = rec.get("ts")
        if not isinstance(ts, (int, float)):
            err(i, f"missing/non-numeric ts: {ts!r}")

        if kind == "span":
            dur = rec.get("dur_s")
            if not isinstance(dur, (int, float)) or dur < 0:
                err(i, f"span needs dur_s >= 0, got {dur!r}")
        elif kind == "event" and name == "train.comm_volume":
            per = rec.get("comm_per_mix_step")
            steps = rec.get("mix_steps")
            total = rec.get("comm_total")
            if (not isinstance(per, (int, float))
                    or not isinstance(steps, int) or steps < 1
                    or not isinstance(total, (int, float))):
                err(i, "comm_volume event needs float comm_per_mix_step, "
                       "int mix_steps >= 1, float comm_total")
            else:
                # replay the engine's exact accumulation: same adds, same
                # order, starting from zero — must match bit-for-bit
                for _ in range(steps):
                    comm_replay += float(per)
                comm_events += 1
                if comm_replay != float(total):
                    err(i, f"comm_volume replay mismatch: engine total "
                           f"{total!r} vs replayed {comm_replay!r}")
        elif kind == "metric":
            mtype = rec.get("type")
            if mtype == "histogram":
                edges = rec.get("edges")
                counts = rec.get("counts")
                count = rec.get("count")
                if (not isinstance(edges, list) or not isinstance(counts, list)
                        or len(counts) != len(edges) + 1):
                    err(i, "histogram needs len(counts) == len(edges) + 1")
                elif sum(counts) != count:
                    err(i, f"histogram counts sum {sum(counts)} != "
                           f"count {count}")
                elif any(b <= a for a, b in zip(edges, edges[1:])):
                    err(i, "histogram edges must be strictly increasing")
            elif mtype == "counter":
                v = rec.get("value")
                if not isinstance(v, (int, float)) or v < 0:
                    err(i, f"counter value must be >= 0, got {v!r}")
                prev = counters_seen.get(name)
                if prev is not None and v < prev:
                    err(i, f"counter {name} went backwards "
                           f"({prev} -> {v})")
                counters_seen[name] = v
            elif mtype != "gauge":
                err(i, f"unknown metric type {mtype!r}")

    if require_comm and comm_events == 0 and not errors:
        errors.append(
            f"{path}: --require-comm: no train.comm_volume events found")
    return errors


def build_parser() -> argparse.ArgumentParser:
    ap = _cli.make_parser(__doc__)
    ap.add_argument("paths", nargs="+", help="JSONL event streams to check")
    ap.add_argument("--require-comm", action="store_true",
                    help="fail streams with no train.comm_volume events")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    failed = False
    for path in args.paths:
        errors = check_stream(path, require_comm=args.require_comm)
        if errors:
            failed = True
            for e in errors:
                print(e, file=sys.stderr)
        else:
            with open(path) as f:
                n = sum(1 for _ in f)
            print(f"{path}: OK ({n} records)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
