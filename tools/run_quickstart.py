#!/usr/bin/env python
"""Execute the README quickstart verbatim, so the docs cannot rot.

Extracts every ``bash`` code fence between the ``<!-- quickstart-begin -->``
and ``<!-- quickstart-end -->`` markers in ``README.md`` and runs each
command through the shell from the repo root.  Whatever a reader would
copy-paste is exactly what CI executes — if a flag is renamed or an entry
point moves, this fails before the doc misleads anyone.

Usage:  python tools/run_quickstart.py [readme_path]
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _cli  # noqa: E402


def extract_commands(readme: str):
    m = re.search(r"<!-- quickstart-begin -->(.*?)<!-- quickstart-end -->",
                  readme, re.S)
    if not m:
        raise SystemExit("README has no quickstart markers")
    commands = []
    for fence in re.findall(r"```bash\n(.*?)```", m.group(1), re.S):
        # join backslash continuations, drop comments/blank lines
        joined = re.sub(r"\\\n\s*", " ", fence)
        for line in joined.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                commands.append(line)
    if not commands:
        raise SystemExit("quickstart markers contain no bash commands")
    return commands


def build_parser():
    p = _cli.make_parser(__doc__)
    p.add_argument("readme", nargs="?", default="README.md",
                   help="README to extract the quickstart fences from")
    return p


def main(argv=None) -> int:
    path = build_parser().parse_args(argv).readme
    root = os.path.dirname(os.path.abspath(path)) or "."
    with open(path, encoding="utf-8") as f:
        commands = extract_commands(f.read())
    for i, cmd in enumerate(commands, 1):
        print(f"[quickstart {i}/{len(commands)}] {cmd}", flush=True)
        t0 = time.time()
        proc = subprocess.run(cmd, shell=True, cwd=root)
        print(f"[quickstart {i}/{len(commands)}] exit={proc.returncode} "
              f"({time.time() - t0:.1f}s)", flush=True)
        if proc.returncode != 0:
            return proc.returncode
    print(f"quickstart OK: {len(commands)} commands")
    return 0


if __name__ == "__main__":
    sys.exit(main())
