#!/usr/bin/env python
"""Execute the README quickstart verbatim, so the docs cannot rot.

Extracts every ``bash`` code fence between the ``<!-- quickstart-begin -->``
and ``<!-- quickstart-end -->`` markers in ``README.md`` and runs each
command through the shell from the repo root.  Whatever a reader would
copy-paste is exactly what CI executes — if a flag is renamed or an entry
point moves, this fails before the doc misleads anyone.

Usage:  python tools/run_quickstart.py [readme_path]
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import time


def extract_commands(readme: str):
    m = re.search(r"<!-- quickstart-begin -->(.*?)<!-- quickstart-end -->",
                  readme, re.S)
    if not m:
        raise SystemExit("README has no quickstart markers")
    commands = []
    for fence in re.findall(r"```bash\n(.*?)```", m.group(1), re.S):
        # join backslash continuations, drop comments/blank lines
        joined = re.sub(r"\\\n\s*", " ", fence)
        for line in joined.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                commands.append(line)
    if not commands:
        raise SystemExit("quickstart markers contain no bash commands")
    return commands


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "README.md"
    root = os.path.dirname(os.path.abspath(path)) or "."
    with open(path, encoding="utf-8") as f:
        commands = extract_commands(f.read())
    for i, cmd in enumerate(commands, 1):
        print(f"[quickstart {i}/{len(commands)}] {cmd}", flush=True)
        t0 = time.time()
        proc = subprocess.run(cmd, shell=True, cwd=root)
        print(f"[quickstart {i}/{len(commands)}] exit={proc.returncode} "
              f"({time.time() - t0:.1f}s)", flush=True)
        if proc.returncode != 0:
            return proc.returncode
    print(f"quickstart OK: {len(commands)} commands")
    return 0


if __name__ == "__main__":
    sys.exit(main())
