#!/usr/bin/env python
"""Run the repo's static-analysis lane: AST lints + the HLO contract matrix.

Two halves (see docs/ANALYSIS.md):

* **Lints** (``repro.analysis.lint``, stdlib-only): tracer-hazard,
  f32-accumulator, and thread-discipline rules over ``src/repro``, with
  a checked suppression baseline — every waiver needs a justification,
  and stale waivers are themselves errors.
* **Contracts** (``repro.analysis.matrix``, needs jax): lowers the four
  compiled programs (fused train chunk, pipelined train chunk, scan
  decode, continuous decode) and asserts their collective footprint,
  permute topology, donation aliasing, wire dtypes, compile counts, and
  host-side f64 comm accounting against the optimized HLO.

The contract matrix needs a multi-device host; this script injects
``--xla_force_host_platform_device_count=8`` into ``XLA_FLAGS`` before
jax ever loads (jax locks the device count at first init), so it must
stay the process entry point — don't import it after jax.

Exits non-zero on any lint violation, stale baseline entry, or contract
violation.

Usage::

    PYTHONPATH=src python tools/run_analysis.py
    PYTHONPATH=src python tools/run_analysis.py --skip-contracts
    PYTHONPATH=src python tools/run_analysis.py --entries scan_decode
"""

from __future__ import annotations

import os
import sys

# must precede any (transitive) jax import — the matrix needs the forced
# multi-device CPU host and jax reads XLA_FLAGS exactly once
_FORCE = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FORCE).strip()

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)  # running via an absolute path
for _p in (os.path.join(_ROOT, "src"),):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import _cli  # noqa: E402
from repro.analysis import lint  # noqa: E402  (stdlib-only, no jax)

DEFAULT_BASELINE = os.path.join("tools", "analysis_baseline.txt")

# mirrors repro.analysis.matrix.ENTRIES without importing jax at
# parser-build time; tests/test_analysis.py asserts they stay in sync
MATRIX_ENTRIES = ("train_chunk", "pipelined_train", "scan_decode",
                  "continuous_decode", "speculative_decode")


def build_parser():
    p = _cli.make_parser(__doc__)
    p.add_argument("--root", default=_ROOT,
                   help="repo root to lint (default: this checkout)")
    p.add_argument("--baseline", default=None,
                   help=f"checked suppression baseline (default: "
                        f"{DEFAULT_BASELINE} under --root when present)")
    p.add_argument("--rules", nargs="+", choices=lint.RULES, default=None,
                   help="restrict lints to these rules (default: all)")
    p.add_argument("--entries", nargs="+", choices=MATRIX_ENTRIES,
                   default=None,
                   help="restrict the contract matrix to these entries "
                        "(default: all four)")
    p.add_argument("--skip-lint", action="store_true",
                   help="skip the AST lints")
    p.add_argument("--skip-contracts", action="store_true",
                   help="skip the HLO contract matrix (no jax import)")
    return p


def _run_lints(args) -> int:
    violations = lint.lint_tree(args.root)
    if args.rules:
        violations = [v for v in violations if v.rule in args.rules]
    baseline_path = args.baseline
    if baseline_path is None:
        cand = os.path.join(args.root, DEFAULT_BASELINE)
        baseline_path = cand if os.path.exists(cand) else None
    stale = []
    if baseline_path:
        try:
            baseline = lint.load_baseline(baseline_path)
        except (OSError, ValueError) as e:
            print(f"analysis: bad baseline: {e}", file=sys.stderr)
            return 1
        violations, stale = lint.apply_baseline(violations, baseline)
        if args.rules:
            stale = [k for k in stale if k.split(":", 1)[0] in args.rules]
    for v in violations:
        print(f"{v.path}:{v.line}: [{v.rule}] {v.message}", file=sys.stderr)
    for k in stale:
        print(f"stale baseline entry (matches nothing, remove it): {k}",
              file=sys.stderr)
    n = len(violations) + len(stale)
    if n:
        print(f"analysis: lint FAILED ({len(violations)} violation(s), "
              f"{len(stale)} stale waiver(s))", file=sys.stderr)
        return 1
    print("analysis: lint OK "
          f"(rules: {', '.join(args.rules or lint.RULES)})")
    return 0


def _run_contracts(args) -> int:
    from repro.analysis import contracts, matrix

    assert matrix.ENTRIES == MATRIX_ENTRIES, \
        "update MATRIX_ENTRIES to match repro.analysis.matrix.ENTRIES"
    entries = tuple(args.entries) if args.entries else None
    try:
        results = matrix.run_matrix(entries)
    except contracts.ContractViolation as e:
        print(f"analysis: contract matrix FAILED\n{e}", file=sys.stderr)
        return 1
    for name, r in results.items():
        print(f"analysis: contract {name} OK (compiles={r['compiles']})")
    return 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    rc = 0
    if not args.skip_lint:
        rc |= _run_lints(args)
    if not args.skip_contracts:
        rc |= _run_contracts(args)
    if rc == 0:
        print("analysis: OK")
    return rc


if __name__ == "__main__":
    sys.exit(main())
