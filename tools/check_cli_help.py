#!/usr/bin/env python
"""CLI help lint: every flag on every launcher must document itself.

Imports each ``repro.launch`` CLI, captures its ``ArgumentParser`` by
intercepting ``parse_args`` (no training/serving code ever runs), and
fails if any action is missing a help string — a flag without help is
invisible in ``--help`` output, which is the only discovery surface the
launchers have.  Also renders each parser's full ``--help`` text, so a
formatting crash (bad ``%`` escapes and the like) fails CI here instead
of in a user's terminal.

Usage:  PYTHONPATH=src python tools/check_cli_help.py
"""

from __future__ import annotations

import argparse
import importlib
import sys

CLI_MODULES = [
    "repro.launch.train",
    "repro.launch.serve",
    "repro.launch.dryrun",
]


class _Captured(Exception):
    def __init__(self, parser: argparse.ArgumentParser):
        self.parser = parser


def capture_parser(main) -> argparse.ArgumentParser:
    """Run ``main([])`` just far enough to grab the parser it builds."""
    orig = argparse.ArgumentParser.parse_args

    def grab(self, args=None, namespace=None):
        raise _Captured(self)

    argparse.ArgumentParser.parse_args = grab
    try:
        main([])
    except _Captured as c:
        return c.parser
    finally:
        argparse.ArgumentParser.parse_args = orig
    raise RuntimeError("main() returned without calling parse_args")


def main() -> int:
    failures = []
    n_flags = 0
    for modname in CLI_MODULES:
        mod = importlib.import_module(modname)
        parser = capture_parser(mod.main)
        for action in parser._actions:
            if isinstance(action, argparse._HelpAction):
                continue
            n_flags += 1
            name = "/".join(action.option_strings) or action.dest
            if not action.help or not action.help.strip():
                failures.append(f"{modname}: {name} has no help text")
        # formatting must not crash (argparse evaluates %-escapes lazily)
        parser.format_help()
    if failures:
        print("\n".join(failures), file=sys.stderr)
        print(f"\n{len(failures)} undocumented flag(s)", file=sys.stderr)
        return 1
    print(f"checked {len(CLI_MODULES)} CLIs, {n_flags} flags documented: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
