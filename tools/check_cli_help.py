#!/usr/bin/env python
"""CLI help lint: every flag on every launcher and tool must document itself.

Imports each ``repro.launch`` CLI, captures its ``ArgumentParser`` by
intercepting ``parse_args`` (no training/serving code ever runs), and
fails if any action is missing a help string — a flag without help is
invisible in ``--help`` output, which is the only discovery surface the
launchers have.  Also renders each parser's full ``--help`` text, so a
formatting crash (bad ``%`` escapes and the like) fails CI here instead
of in a user's terminal.

The ``tools/`` scripts get the same treatment through their shared
``build_parser()`` surface (``tools/_cli.py``) — no interception needed,
the parser is constructed directly and side-effect free.

Usage:  PYTHONPATH=src python tools/check_cli_help.py
"""

from __future__ import annotations

import argparse
import importlib
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _cli  # noqa: E402

CLI_MODULES = [
    "repro.launch.train",
    "repro.launch.serve",
    "repro.launch.dryrun",
]

# tools expose build_parser() per tools/_cli.py; importable because this
# script's own directory leads sys.path
TOOL_MODULES = [
    "check_links",
    "check_metrics_schema",
    "run_quickstart",
    "run_analysis",
    "check_cli_help",
]


class _Captured(Exception):
    def __init__(self, parser: argparse.ArgumentParser):
        self.parser = parser


def capture_parser(main) -> argparse.ArgumentParser:
    """Run ``main([])`` just far enough to grab the parser it builds."""
    orig = argparse.ArgumentParser.parse_args

    def grab(self, args=None, namespace=None):
        raise _Captured(self)

    argparse.ArgumentParser.parse_args = grab
    try:
        main([])
    except _Captured as c:
        return c.parser
    finally:
        argparse.ArgumentParser.parse_args = orig
    raise RuntimeError("main() returned without calling parse_args")


def _lint_parser(modname: str, parser: argparse.ArgumentParser,
                 failures: list) -> int:
    n_flags = 0
    for action in parser._actions:
        if isinstance(action, argparse._HelpAction):
            continue
        n_flags += 1
        name = "/".join(action.option_strings) or action.dest
        if not action.help or not action.help.strip():
            failures.append(f"{modname}: {name} has no help text")
    # formatting must not crash (argparse evaluates %-escapes lazily)
    parser.format_help()
    if not parser.description:
        failures.append(f"{modname}: parser has no description")
    return n_flags


def build_parser() -> argparse.ArgumentParser:
    return _cli.make_parser(__doc__)


def main(argv=None) -> int:
    build_parser().parse_args(argv)
    failures: list = []
    n_flags = 0
    for modname in CLI_MODULES:
        mod = importlib.import_module(modname)
        n_flags += _lint_parser(modname, capture_parser(mod.main), failures)
    for modname in TOOL_MODULES:
        mod = importlib.import_module(modname)
        n_flags += _lint_parser(f"tools/{modname}", mod.build_parser(),
                                failures)
    if failures:
        print("\n".join(failures), file=sys.stderr)
        print(f"\n{len(failures)} undocumented flag(s)", file=sys.stderr)
        return 1
    print(f"checked {len(CLI_MODULES)} CLIs + {len(TOOL_MODULES)} tools, "
          f"{n_flags} flags documented: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
