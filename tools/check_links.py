#!/usr/bin/env python
"""Markdown link checker (stdlib-only, no network).

Walks every ``*.md`` file in the repo and verifies that each relative
link target exists on disk (anchors are stripped; http(s)/mailto links
are skipped — CI must not depend on the network).  Exits non-zero with a
list of broken links, so documentation cannot reference files that were
moved or never written.

Usage:  python tools/check_links.py [repo_root]
"""

from __future__ import annotations

import os
import re
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _cli  # noqa: E402

# [text](target) — excluding images is pointless, they must exist too
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}


def iter_markdown(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_file(path: str):
    """(number of relative links, [(lineno, target, resolved) broken])."""
    n_links = 0
    broken = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                n_links += 1
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), rel))
                if not os.path.exists(resolved):
                    broken.append((lineno, target, resolved))
    return n_links, broken


def build_parser():
    p = _cli.make_parser(__doc__)
    p.add_argument("root", nargs="?", default=".",
                   help="repo root to walk for *.md files (default: cwd)")
    return p


def main(argv=None) -> int:
    root = os.path.abspath(build_parser().parse_args(argv).root)
    n_files = n_links = 0
    failures = []
    for path in sorted(iter_markdown(root)):
        n_files += 1
        links, broken = check_file(path)
        n_links += links
        for lineno, target, resolved in broken:
            failures.append(f"{os.path.relpath(path, root)}:{lineno}: "
                            f"broken link {target!r} -> {resolved}")
    if failures:
        print("\n".join(failures), file=sys.stderr)
        print(f"\n{len(failures)} broken link(s)", file=sys.stderr)
        return 1
    print(f"checked {n_files} markdown files, {n_links} relative links: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
